"""Paper Fig. 11: Global Buffer access breakdown by operand (Adj/Inp/Int/
Wt/Op/Psum) for Mutag (LEF) and Citeseer (HF)."""
from __future__ import annotations

from repro.core import TABLE5_NAMES, TileStats, named_skeleton, optimize_tiles

from .common import emit, save_json, timed, workloads


def run():
    rows, table = [], {}
    for name, spec, wl in workloads(["mutag", "citeseer"]):
        table[name] = {}
        ts = TileStats(wl.nnz)
        for sk in TABLE5_NAMES:
            try:
                res, us = timed(
                    optimize_tiles, named_skeleton(sk), wl,
                    objective="cycles", pe_splits=(0.25, 0.5, 0.75),
                    tile_stats=ts,
                )
            except (RuntimeError, ValueError):
                continue
            acc = res.stats.gb_accesses
            table[name][sk] = acc
            top = max(acc, key=acc.get)
            rows.append((f"fig11/{name}/{sk}", us, f"dominant={top}"))
    save_json("fig11_gb_breakdown", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
