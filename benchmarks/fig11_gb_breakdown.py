"""Paper Fig. 11: Global Buffer access breakdown by operand (Adj/Inp/Int/
Wt/Op/Psum) for Mutag (LEF) and Citeseer (HF)."""
from __future__ import annotations

from .common import emit, save_json, skeleton_sweep, workloads


def run():
    rows, table = [], {}
    for name, spec, wl in workloads(["mutag", "citeseer"]):
        table[name] = {}
        for sk, res, us in skeleton_sweep(wl):
            acc = res.stats.gb_accesses
            table[name][sk] = acc
            top = max(acc, key=acc.get)
            rows.append((f"fig11/{name}/{sk}", us, f"dominant={top}"))
    save_json("fig11_gb_breakdown", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
