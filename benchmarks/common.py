"""Shared benchmark plumbing: timing, CSV emission and the sweep loops the
fig9-fig13 modules have in common (mapper-chosen skeleton sweeps and the
batched-vs-scalar hardware-axis speedup measurement)."""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (
    GNNLayerWorkload,
    TABLE5_NAMES,
    TileStats,
    named_skeleton,
    optimize_tiles,
)
from repro.graphs import TABLE4, load_dataset

G_HIDDEN = 16  # Kipf-standard GCN hidden width (see EXPERIMENTS.md)
OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "benchmarks"


def workloads(datasets=None):
    for name in datasets or TABLE4:
        g, spec = load_dataset(name)
        yield name, spec, GNNLayerWorkload(g.nnz, spec.n_features, G_HIDDEN, name=name)


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds


def emit(rows: list[tuple[str, float, str]]):
    """Print the assignment CSV: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def save_json(name: str, payload):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(payload, indent=2))


def skeleton_sweep(
    wl,
    names=TABLE5_NAMES,
    objective: str = "cycles",
    pe_splits=(0.25, 0.5, 0.75),
    tile_stats: TileStats | None = None,
):
    """The fig9/10/11 inner loop: mapper-chosen tilings for each skeleton,
    one shared TileStats ladder per workload.  Yields
    ``(skeleton_name, MappingResult, us)``; infeasible skeletons are
    skipped."""
    ts = tile_stats if tile_stats is not None else TileStats(wl.nnz)
    for sk in names:
        try:
            res, us = timed(
                optimize_tiles,
                named_skeleton(sk),
                wl,
                objective=objective,
                pe_splits=pe_splits,
                tile_stats=ts,
            )
        except (RuntimeError, ValueError):
            continue
        yield sk, res, us


def speedup_entry(batch_us: float, scalar_us: float, n_points: int) -> dict:
    """Evidence-JSON fragment for a batched-vs-per-point-scalar hw sweep."""
    return {
        "batch_us": batch_us,
        "scalar_us": scalar_us,
        "hw_points": n_points,
        "speedup": scalar_us / max(batch_us, 1e-9),
    }


def check_speedup(fig: str, dataset: str, speedup: float, floor: float) -> list[str]:
    """Wall-clock guard: the batched hw axis must beat the per-point scalar
    loop by at least ``floor``x.  Returns error strings (caller raises after
    evidence is saved, so a regression still leaves the JSON behind)."""
    if speedup < floor:
        return [
            f"{fig}/{dataset}: batched hw sweep only {speedup:.1f}x faster "
            f"than the per-point scalar loop (floor {floor:.0f}x)"
        ]
    return []
