"""Shared benchmark plumbing: timing + CSV emission."""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import GNNLayerWorkload
from repro.graphs import TABLE4, load_dataset

G_HIDDEN = 16  # Kipf-standard GCN hidden width (see EXPERIMENTS.md)
OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "benchmarks"


def workloads(datasets=None):
    for name in datasets or TABLE4:
        g, spec = load_dataset(name)
        yield name, spec, GNNLayerWorkload(g.nnz, spec.n_features, G_HIDDEN, name=name)


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds


def emit(rows: list[tuple[str, float, str]]):
    """Print the assignment CSV: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def save_json(name: str, payload):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(payload, indent=2))
