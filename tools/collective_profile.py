"""Rank collectives in a dry-run cell by execution-weighted link bytes,
with the originating jax op (metadata op_name) — the dry-run 'profiler'."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys

from repro.launch.dryrun import build_step
from repro.launch.hlo import _split_computations, execution_counts, _OP_RE, _GROUP_RE, shape_bytes
from repro.launch.mesh import make_production_mesh
from repro.configs import SHAPES, get_config
from repro.models import production_rules, use_sharding
from repro.models.sharding import tuned_rules
import jax

def profile(arch, shape_name, top=18, tuned=False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = tuned_rules(arch) if tuned else production_rules()
    with use_sharding(mesh, rules):
        fn, args, shardings, donate = build_step(cfg, shape, mesh, rules)
        with jax.set_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=shardings,
                               donate_argnums=donate or None).lower(*args).compile()
    hlo = compiled.as_text()
    comps = _split_computations(hlo)
    mult = execution_counts(hlo)
    entries = []
    for comp, lines in comps.items():
        m_c = mult.get(comp, 1)
        for line in lines:
            if "-done(" in line:
                continue
            m = _OP_RE.search(line)
            if not m:
                continue
            op = m.group("op")
            result = shape_bytes(m.group("result"))
            gm = _GROUP_RE.search(line)
            p = int(gm.group(2)) if gm else 1
            name = re.search(r'op_name="([^"]+)"', line)
            nm = name.group(1) if name else "?"
            shp = re.search(r"=\s+(\S+)", line)
            entries.append((result * m_c, op, p, m_c, shp.group(1) if shp else "?", nm[-110:]))
    entries.sort(reverse=True)
    print(f"== {arch} x {shape_name}: top collectives by executed result bytes ==")
    for b, op, p, m_c, shp, nm in entries[:top]:
        print(f"{b/1e9:9.2f}GB x{m_c:5d} P={p:3d} {op:18s} {shp:28s} {nm}")

if __name__ == "__main__":
    profile(sys.argv[1], sys.argv[2], tuned=("--tuned" in sys.argv))
